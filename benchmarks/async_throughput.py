"""Async-engine benchmark: buffer/staleness sweep at a fixed event budget.

The point of the event-driven executor (repro/core/events/) is that
dropping the round barrier trades per-round freshness for wall-clock
throughput: servers aggregate whenever their buffer fills instead of
waiting for the slowest cohort member.  This sweep makes that measurable —
at a FIXED candidate-event budget (ticks x P x rate is held constant) it
runs the scan-compiled executor over a grid of buffer sizes x staleness
bounds and reports, per configuration,

  * events/sec (folded arrivals per second of the compiled run), and
  * ticks-to-target-loss: first tick at/below the synchronous engine's
    median MSD, against the sync engine's own ticks-to-target on the same
    arrival bandwidth (cohort L = rate per round),

plus the realized release cadence (mean flushes per server).

    PYTHONPATH=src python benchmarks/async_throughput.py            # full
    PYTHONPATH=src python benchmarks/async_throughput.py --reduced  # CI smoke

Writes the repo-root ``BENCH_async.json`` (the second datapoint of the
perf trajectory, after BENCH_population.json) and prints ``name,value``
rows for the harness (benchmarks/run.py).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.configs.base import GFLConfig
from repro.core.events import parse_async_spec, run_gfl_async
from repro.core.population import SyntheticPopulation, estimate_w_ref

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_async.json")

BUFFERS = (4, 8, 16)
STALE_BOUNDS = (2, 4)


def ticks_to_target(msd: np.ndarray, target: float) -> int:
    """First tick index at/below target, or -1 if never reached."""
    hit = np.nonzero(msd <= target)[0]
    return int(hit[0]) if hit.size else -1


def bench_one(pop, cfg: GFLConfig, spec_str: str, *, ticks: int,
              batch_size: int, w_ref, target: float) -> dict:
    spec = parse_async_spec(spec_str)
    cfg = GFLConfig(**{**cfg.__dict__, "async_spec": spec_str})
    # warmup compiles the scan program; the timed run reuses it
    run_gfl_async(pop, cfg, ticks=2, batch_size=batch_size, seed=0,
                  w_ref=w_ref, scan=True)
    t0 = time.time()
    res = run_gfl_async(pop, cfg, ticks=ticks, batch_size=batch_size,
                        seed=0, w_ref=w_ref, scan=True)
    jax.block_until_ready(res.params)
    dt = time.time() - t0
    events = int(res.events.sum())
    return {
        "buffer": spec.buffer, "max_stale": spec.max_stale,
        "rate": spec.events_per_tick, "ticks": ticks,
        "events_folded": events,
        "events_per_sec": events / dt,
        "seconds": dt,
        "releases_per_server_mean": float(res.flushed.sum(0).mean()),
        "mean_staleness": float(res.staleness.mean()),
        "dropped_stale": int(res.dropped_stale.sum()),
        "msd_final": float(res.msd[-1]),
        "ticks_to_target": ticks_to_target(res.msd, target),
    }


def run(quick: bool = False, reduced: bool = False,
        ticks: int | None = None, P: int = 8, K: int = 400,
        rate: int = 8, batch_size: int = 10):
    reduced = bool(quick or reduced)
    if reduced:
        P, K, rate = 4, 100, 4
        ticks = 40 if ticks is None else ticks
    ticks = 150 if ticks is None else ticks
    buffers = tuple(max(2, b // 2) for b in BUFFERS) if reduced else BUFFERS

    pop = SyntheticPopulation(P, K, mode="hetero", N=50, M=2, data_seed=0)
    w_ref = estimate_w_ref(pop, sample_clients=min(32, K), iters=500)
    base = GFLConfig(num_servers=P, clients_per_server=K,
                     clients_sampled=rate, topology="ring",
                     privacy="hybrid", sigma_g=0.05, mu=0.1,
                     grad_bound=10.0,
                     cohort="uniform+trace:diurnal,period=12,min=0.4")

    # synchronous baseline on the same arrival bandwidth: the sync-limit
    # spec (buffer = rate, zero latency) IS run_gfl_population's pure path
    sync_cfg = GFLConfig(**{**base.__dict__, "cohort": "uniform",
                            "async_spec": f"async:buffer={rate}"})
    run_gfl_async(pop, sync_cfg, ticks=2, batch_size=batch_size, seed=0,
                  w_ref=w_ref, scan=True)
    t0 = time.time()
    sync = run_gfl_async(pop, sync_cfg, ticks=ticks, batch_size=batch_size,
                         seed=0, w_ref=w_ref, scan=True)
    jax.block_until_ready(sync.params)
    sync_dt = time.time() - t0
    target = float(np.median(sync.msd))
    sync_row = {
        "events_per_sec": int(sync.events.sum()) / sync_dt,
        "msd_final": float(sync.msd[-1]),
        "ticks_to_target": ticks_to_target(sync.msd, target),
        "target_msd": target, "seconds": sync_dt,
    }

    rows = [bench_one(pop, base,
                      f"async:buffer={b},latency=lognorm:0.5,"
                      f"max_stale={s},rate={rate}",
                      ticks=ticks, batch_size=batch_size, w_ref=w_ref,
                      target=target)
            for b in buffers for s in STALE_BOUNDS]
    assert len({r["buffer"] for r in rows}) >= 3, \
        "the sweep must cover >= 3 buffer sizes"

    from benchmarks.meta import write_bench
    write_bench(OUT, {"benchmark": "async_throughput", "reduced": reduced,
                      "P": P, "K": K, "rate": rate, "ticks": ticks,
                      "sync": sync_row, "rows": rows},
                headline={
                    "sync_events_per_sec":
                        ("higher", sync_row["events_per_sec"]),
                    "peak_events_per_sec":
                        ("higher",
                         max(r["events_per_sec"] for r in rows)),
                })

    out = [("async_throughput/sync_events_per_sec",
            sync_row["events_per_sec"]),
           ("async_throughput/sync_ticks_to_target",
            sync_row["ticks_to_target"])]
    for r in rows:
        tag = f"buf{r['buffer']}_stale{r['max_stale']}"
        out.append((f"async_throughput/{tag}_events_per_sec",
                    r["events_per_sec"]))
        out.append((f"async_throughput/{tag}_ticks_to_target",
                    float(r["ticks_to_target"])))
        out.append((f"async_throughput/{tag}_releases_per_server",
                    r["releases_per_server_mean"]))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="CPU smoke: fewer ticks, smaller P/K/rate (the "
                         "buffer x staleness grid keeps >= 3 buffer sizes "
                         "— that is the point)")
    ap.add_argument("--ticks", type=int, default=None,
                    help="event batches per config (default: 150 full / "
                         "40 reduced)")
    args = ap.parse_args(argv)
    for name, val in run(reduced=args.reduced, ticks=args.ticks):
        print(f"{name},{val:.6g}")


if __name__ == "__main__":
    main()
