"""Theorem 2 benchmark: the privacy schedule.

(a) eps(i) = sqrt(2) mu B (1+i) i / sigma for fixed sigma (quadratic decay of
    privacy), and (b) the sigma needed to pin eps at a target for growing
    horizons (the utility cost of privacy, feeding Theorem 1's O(mu) term).
"""
from __future__ import annotations

import csv
import os

from repro.core.privacy.accountant import epsilon_at, sigma_for_epsilon

OUT = os.path.join(os.path.dirname(__file__), "results")


def run(mu: float = 0.1, B: float = 10.0, quick: bool = False):
    horizons = [1, 10, 50, 100, 500, 1000]
    rows = []
    for i in horizons:
        eps_fixed = epsilon_at(i, mu, B, sigma_g=0.2)
        sig_needed = sigma_for_epsilon(i, mu, B, eps=2.0)
        rows.append((i, eps_fixed, sig_needed))
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "privacy_epsilon.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["iteration", "eps_at_sigma0.2", "sigma_for_eps2"])
        w.writerows(rows)
    # quadratic-decay check as a derived metric
    q = rows[-1][1] / rows[-3][1]           # eps(1000)/eps(100) ~ 100.8x
    return [("privacy/eps_1000_over_eps_100", q),
            ("privacy/sigma_for_eps2_at_1000", rows[-1][2])]


if __name__ == "__main__":
    for name, val in run():
        print(f"{name},{val:.6g}")
