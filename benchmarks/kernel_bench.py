"""Kernel bench: per-kernel us/call + END-TO-END fused-round-pipeline rows.

Per-kernel timings run in interpret mode on CPU — numbers are
correctness-path timings, NOT TPU performance; the TPU story is the
analytic HBM-traffic accounting (``launch/roofline.round_pipeline_traffic``)
that the round rows carry alongside the measured CPU timings.

The round rows compare three realizations of one GFL round
(clip -> update -> privatize -> fold -> combine) over [P, L, D]:

  unfused_chain  the reference op chain with every stage in its own jit
                 compartment (forced HBM materialization between stages —
                 what the pre-kernel mechanism path paid);
  fused_ref      the SAME one-pass pipeline through the dispatch layer's
                 jnp backend (``repro.kernels.ops`` with backend="ref"),
                 one jit — the CPU realization of the fusion;
  fused_pallas   the Pallas kernels (interpret mode on CPU).

``python benchmarks/kernel_bench.py [--reduced]`` writes repo-root
``BENCH_kernels.json`` — the kernel-perf trajectory's first datapoint —
with, per mode, the analytic ref-vs-fused HBM bytes (fused must do <= 1/2
the round trips of the reference chain) and the measured round speedup
(unfused_chain / fused_ref).
"""
from __future__ import annotations

import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core.topology import combination_matrix
from repro.kernels import ops, ref
from repro.launch.roofline import HBM_BW, round_pipeline_traffic

REPO_ROOT = Path(__file__).resolve().parents[1]


def _time(fn, *args, iters=5):
    out = fn(*args)                       # compile + warmup, exactly once
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


# ---------------------------------------------------------------------------
# per-kernel micro rows
# ---------------------------------------------------------------------------


def micro_rows(quick: bool = False):
    P, D, L = 16, 8192 if not quick else 2048, 8
    A = jnp.asarray(combination_matrix("ring", P), jnp.float32)
    key = jax.random.PRNGKey(0)  # fixed bench seed: reproducible trajectory  # gflint: disable=GFL001
    psi = jax.random.normal(key, (P, D))
    g = jax.random.normal(jax.random.fold_in(key, 1), (P, D))
    upd = jax.random.normal(jax.random.fold_in(key, 2), (L, D))
    u = jax.random.uniform(jax.random.fold_in(key, 3), (P, D),
                           minval=-0.499, maxval=0.499)
    seed = jnp.array([7], jnp.uint32)

    at = A.T
    return [
        ("kernel/graph_combine_us", _time(ops.graph_combine, A, psi, g)),
        ("oracle/graph_combine_us",
         _time(jax.jit(ref.graph_combine_ref), at, psi, g)),
        ("kernel/secure_agg_us", _time(ops.secure_agg_mean, upd, seed)),
        ("kernel/laplace_us", _time(lambda x: ops.laplace_transform(x, 0.5),
                                    u)),
        ("oracle/laplace_us",
         _time(jax.jit(lambda x: ref.laplace_transform_ref(x, 0.5)), u)),
        ("kernel/clip_accum_us", _time(lambda x: ops.clip_accum(x, 1.0),
                                       upd)),
        ("oracle/clip_accum_us",
         _time(jax.jit(lambda x: ref.clip_accum_ref(x, 1.0)), upd)),
    ]


# ---------------------------------------------------------------------------
# end-to-end round pipeline rows
# ---------------------------------------------------------------------------


def _unfused_chain(A, mode, L, D):
    """The pre-kernel reference chain, one jit compartment per stage so
    every intermediate round-trips HBM (what separate XLA dispatches pay).
    The privatize stage is the REFERENCE mechanism's: threefry pairwise
    mask streams (``pairwise_masks_vec``) for "mask", the reference
    Laplace sampler for "laplace" — the in-round cost the
    ``use_kernels=False`` hybrid / iid_dp client levels actually pay."""
    from repro.core.privacy.noise import sample_laplace
    from repro.core.privacy.secure_agg import pairwise_masks_vec

    norms = jax.jit(lambda g: jnp.sqrt(jnp.sum(g * g, axis=-1)))
    scale = jax.jit(lambda n, b: jnp.minimum(1.0, b / jnp.maximum(n, 1e-12)))
    update = jax.jit(lambda w, g, c, mu: w[:, None] - mu * c[..., None] * g)
    mask = jax.jit(lambda u, ks: u + jax.vmap(
        lambda k: pairwise_masks_vec(k, L, D, 0.3))(ks))  # bench-only release site (timing, no data)  # gflint: disable=GFL002
    lap = jax.jit(lambda u, ks: u + jax.vmap(
        lambda k: sample_laplace(k, (L, D), 0.3))(ks))
    fold = jax.jit(lambda u: u.mean(axis=1))
    combine = jax.jit(lambda A, p, g: ref.graph_combine_ref(A.T, p, g))

    def run(w, grads, keys, gn, bound=10.0, mu=0.1):
        n = norms(grads)
        c = scale(n, bound)
        upd = update(w, grads, c, mu)
        if mode == "mask":
            upd = mask(upd, keys)
        elif mode == "laplace":
            upd = lap(upd, keys)
        psi = fold(upd)
        return combine(A, psi, gn)

    return run


def _fused(A, mode, backend, L, D):
    """One-jit fused pipeline through the dispatch layer — including the
    mechanism's in-round noise work (seed derivation / reference Laplace
    draws), mirroring what ``_fused_client_fold`` runs per round."""
    from repro.core.privacy.noise import sample_laplace

    sigma = 0.0 if mode == "none" else 0.3

    @jax.jit
    def run(w, grads, keys, gn):
        seeds = noise = None
        if mode == "mask":
            seeds = jax.vmap(
                lambda k: jax.random.randint(k, (1,), 0, 2**31 - 1)[0]
            )(keys).astype(jnp.uint32)
        elif mode == "laplace":
            noise = jax.vmap(lambda k: sample_laplace(k, (L, D), sigma)
                             )(keys)
        psi, _ = ops.round_fold(  # bench-only release site (timing, no data)  # gflint: disable=GFL002
            w, grads, mu=0.1, bound=10.0, mode=mode, sigma=sigma,
            seeds=seeds, noise=noise, backend=backend)
        return ops.graph_combine(A, psi, gn, backend=backend)

    return run


def round_rows(quick: bool = False):
    P, L, D = (10, 8, 16384 if not quick else 2048)
    key = jax.random.PRNGKey(0)  # fixed bench seed: reproducible trajectory  # gflint: disable=GFL001
    A = jnp.asarray(combination_matrix("ring", P), jnp.float32)
    w = jax.random.normal(key, (P, D))
    grads = jax.random.normal(jax.random.fold_in(key, 1), (P, L, D))
    gn = jax.random.normal(jax.random.fold_in(key, 3), (P, D)) * 0.3
    keys = jax.random.split(jax.random.fold_in(key, 4), P)

    rows, report = [], []
    for mode in ("mask", "laplace"):
        chain = _unfused_chain(A, mode, L, D)
        t_chain = _time(chain, w, grads, keys, gn, iters=10)
        t_ref = _time(_fused(A, mode, "ref", L, D), w, grads, keys, gn,
                      iters=10)
        t_pal = _time(_fused(A, mode, "pallas", L, D), w, grads, keys,
                      gn, iters=3)
        ref_b = round_pipeline_traffic(P, L, D, mode=mode, fused=False)
        fus_b = round_pipeline_traffic(P, L, D, mode=mode, fused=True)
        ratio = fus_b["total"] / ref_b["total"]
        # gradient-scale HBM round trips — the model-scale headline (the
        # [P, D]-order terms in the byte ratio vanish as D grows)
        trips = fus_b["pld_passes"] / ref_b["pld_passes"]
        speedup = t_chain / t_ref
        # achieved bandwidth: the mode's analytic HBM traffic over the
        # measured wall time, as a fraction of single-chip peak HBM_BW.
        # On CPU these are diagnostics (the fraction reads against a TPU
        # roof), but they make the perf trajectory roofline-anchored.
        ach_ref_gbps = fus_b["total"] / (t_ref * 1e-6) / 1e9
        ach_pal_gbps = fus_b["total"] / (t_pal * 1e-6) / 1e9
        frac_ref = ach_ref_gbps / (HBM_BW / 1e9)
        frac_pal = ach_pal_gbps / (HBM_BW / 1e9)
        rows += [
            (f"round/{mode}/unfused_chain_us", t_chain),
            (f"round/{mode}/fused_ref_us", t_ref),
            (f"round/{mode}/fused_pallas_us", t_pal),
            (f"round/{mode}/hbm_ratio", ratio),
            (f"round/{mode}/roundtrip_ratio", trips),
            (f"round/{mode}/speedup", speedup),
            (f"round/{mode}/achieved_gbps_ref", ach_ref_gbps),
            (f"round/{mode}/achieved_gbps_pallas", ach_pal_gbps),
            (f"round/{mode}/roofline_frac_ref", frac_ref),
            (f"round/{mode}/roofline_frac_pallas", frac_pal),
        ]
        report.append({
            "name": "round_pipeline", "mode": mode, "P": P, "L": L, "D": D,
            "ref_hbm_bytes": ref_b["total"],
            "fused_hbm_bytes": fus_b["total"],
            "hbm_ratio": ratio,
            "ref_pld_passes": ref_b["pld_passes"],
            "fused_pld_passes": fus_b["pld_passes"],
            "roundtrip_ratio": trips,
            "ref_hbm_terms": ref_b, "fused_hbm_terms": fus_b,
            "unfused_chain_us": t_chain, "fused_ref_us": t_ref,
            "fused_pallas_us": t_pal, "round_speedup": speedup,
            "achieved_gbps_ref": ach_ref_gbps,
            "achieved_gbps_pallas": ach_pal_gbps,
            "roofline_frac_ref": frac_ref,
            "roofline_frac_pallas": frac_pal,
            "roof_gbps": HBM_BW / 1e9,
        })
    return rows, report


def run(quick: bool = False):
    """benchmarks/run.py entry: per-kernel micro rows (see ``run_round``
    for the end-to-end pipeline rows)."""
    return micro_rows(quick)


def run_round(quick: bool = False):
    """benchmarks/run.py entry: fused-round-pipeline rows; also refreshes
    repo-root BENCH_kernels.json."""
    rows, report = round_rows(quick)
    _write_json(report, reduced=quick)
    return rows


def _write_json(report, reduced: bool):
    payload = {
        "bench": "kernel_round_pipeline",
        "backend": jax.default_backend(),
        "reduced": bool(reduced),
        "note": ("CPU timings run the Pallas kernels in interpret mode "
                 "(correctness path); hbm_ratio is the analytic TPU "
                 "round-trip accounting from launch/roofline.py"),
        "rows": report,
    }
    # headline: per-mode analytic byte ratio (deterministic -> tight tol)
    # and measured chain-vs-fused speedup (CPU timing -> generous tol)
    headline = {}
    for r in report:
        headline[f"{r['mode']}_hbm_ratio"] = ("lower", r["hbm_ratio"], 0.01)
        headline[f"{r['mode']}_speedup"] = ("higher", r["round_speedup"],
                                            0.5)
    from benchmarks.meta import write_bench
    return write_bench(REPO_ROOT / "BENCH_kernels.json", payload,
                       headline=headline)


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="CPU smoke sizes (CI)")
    args = ap.parse_args(argv)
    for name, val in micro_rows(quick=args.reduced):
        print(f"{name},{val:.1f}")
    rows, report = round_rows(quick=args.reduced)
    for name, val in rows:
        print(f"{name},{val:.4g}")
    out = _write_json(report, reduced=args.reduced)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
