"""Kernel micro-bench: us/call for each Pallas kernel (interpret mode on CPU
— numbers are correctness-path timings, NOT TPU performance; the TPU story
is the §Roofline HBM-traffic analysis) and the jnp oracle for comparison.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.topology import combination_matrix
from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = False):
    P, D, L = 16, 8192 if not quick else 2048, 8
    A = jnp.asarray(combination_matrix("ring", P), jnp.float32)
    key = jax.random.PRNGKey(0)
    psi = jax.random.normal(key, (P, D))
    g = jax.random.normal(jax.random.fold_in(key, 1), (P, D))
    upd = jax.random.normal(jax.random.fold_in(key, 2), (L, D))
    u = jax.random.uniform(jax.random.fold_in(key, 3), (P, D),
                           minval=-0.499, maxval=0.499)
    seed = jnp.array([7], jnp.uint32)

    at = A.T
    rows = [
        ("kernel/graph_combine_us", _time(ops.graph_combine, A, psi, g)),
        ("oracle/graph_combine_us",
         _time(jax.jit(ref.graph_combine_ref), at, psi, g)),
        ("kernel/secure_agg_us", _time(ops.secure_agg_mean, upd, seed)),
        ("kernel/laplace_us", _time(lambda x: ops.laplace_transform(x, 0.5),
                                    u)),
        ("oracle/laplace_us",
         _time(jax.jit(lambda x: ref.laplace_transform_ref(x, 0.5)), u)),
        ("kernel/clip_accum_us", _time(lambda x: ops.clip_accum(x, 1.0),
                                       upd)),
        ("oracle/clip_accum_us",
         _time(jax.jit(lambda x: ref.clip_accum_ref(x, 1.0)), upd)),
    ]
    return rows


if __name__ == "__main__":
    for name, val in run():
        print(f"{name},{val:.1f}")
