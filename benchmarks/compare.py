"""Benchmark regression gate: current BENCH payloads vs history.

    python benchmarks/compare.py                 # gate the repo-root payloads
    python benchmarks/compare.py --base-tol 0.4  # looser timing tolerance

For every repo-root ``BENCH_*.json`` that declares headline metrics
(written via ``benchmarks/meta.write_bench``), find the most recent
``BENCH_history.jsonl`` entry for the *same benchmark on the same
backend* that is not the current run, and compare each shared headline
metric against it:

* direction ``higher`` regresses when ``cur < prev - slack``;
* direction ``lower``  regresses when ``cur > prev + slack``;

where ``slack = max(tol * |prev|, abs_tol)``.  The relative tolerance
is noise-aware: a headline declaration may pin its own ``tol``
(deterministic metrics — byte ratios, live-memory budgets — declare a
tight one), otherwise it defaults to ``base_tol / sqrt(repeats)`` using
the ``repeats`` count already in the payload (best-of-N timings
concentrate as N grows).  ``abs_tol`` (default 0) keeps near-zero
metrics such as overhead percentages from tripping on relative noise.
No matching history entry means "first datapoint" — a pass with a
note, never a failure.

Exit 0 when nothing regressed, 1 on any regression (the CI nightly gate),
2 on usage errors.  Pure stdlib — safe to run anywhere.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path
from typing import List, Optional

REPO_ROOT = Path(__file__).resolve().parents[1]
DEFAULT_HISTORY = REPO_ROOT / "BENCH_history.jsonl"
DEFAULT_BASE_TOL = 0.25


def load_history(path: Path) -> List[dict]:
    entries: List[dict] = []
    if not path.exists():
        return entries
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                entries.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return entries


def find_baseline(history: List[dict], payload: dict) -> Optional[dict]:
    """Latest same-benchmark same-backend history entry that is not the
    current run (keyed by (git_sha, timestamp)) and carries headlines."""
    meta = payload.get("meta", {})
    name = payload.get("benchmark") or payload.get("bench")
    cur_key = (meta.get("git_sha"), meta.get("timestamp"))
    for entry in reversed(history):
        if entry.get("benchmark") != name:
            continue
        if entry.get("backend") != meta.get("backend"):
            continue
        if (entry.get("git_sha"), entry.get("timestamp")) == cur_key:
            continue
        if entry.get("headline"):
            return entry
    return None


def metric_tolerance(decl: dict, payload: dict, base_tol: float) -> float:
    if decl.get("tol") is not None:
        return float(decl["tol"])
    repeats = payload.get("repeats") or 1
    try:
        repeats = max(1, int(repeats))
    except (TypeError, ValueError):
        repeats = 1
    return base_tol / math.sqrt(repeats)


def compare_payload(payload: dict, history: List[dict],
                    base_tol: float) -> List[dict]:
    """Rows for one payload: one dict per headline metric with prev/cur/
    tol and a ``status`` of ok | REGRESSION | no-baseline | new-metric."""
    headline = payload.get("headline") or {}
    name = payload.get("benchmark") or payload.get("bench") or "?"
    baseline = find_baseline(history, payload)
    rows = []
    for metric, decl in sorted(headline.items()):
        cur = float(decl["value"])
        row = {"benchmark": name, "metric": metric,
               "direction": decl["direction"], "cur": cur,
               "prev": None, "delta_pct": None, "tol_pct": None,
               "status": "no-baseline"}
        if baseline is not None:
            prev_decl = (baseline.get("headline") or {}).get(metric)
            if prev_decl is None:
                row["status"] = "new-metric"
            else:
                prev = float(prev_decl["value"])
                tol = metric_tolerance(decl, payload, base_tol)
                abs_tol = float(decl.get("abs_tol") or 0.0)
                row["prev"] = prev
                row["tol_pct"] = 100.0 * tol
                if not math.isfinite(prev) or not math.isfinite(cur) \
                        or (prev == 0.0 and abs_tol == 0.0):
                    row["status"] = "skipped (non-comparable baseline)"
                else:
                    if prev != 0.0:
                        row["delta_pct"] = 100.0 * (cur - prev) / abs(prev)
                    slack = max(tol * abs(prev), abs_tol)
                    if decl["direction"] == "higher":
                        bad = cur < prev - slack
                    else:
                        bad = cur > prev + slack
                    row["status"] = "REGRESSION" if bad else "ok"
        rows.append(row)
    return rows


def _fmt(v, width=12) -> str:
    if v is None:
        return f"{'-':>{width}}"
    return f"{v:>{width}.6g}"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python benchmarks/compare.py",
        description="Diff current BENCH payload headlines against the "
                    "last same-backend BENCH_history.jsonl entries.")
    ap.add_argument("--root", type=Path, default=REPO_ROOT,
                    help="directory holding the BENCH_*.json payloads")
    ap.add_argument("--history", type=Path, default=None,
                    help="history JSONL (default: <root>/BENCH_history"
                         ".jsonl)")
    ap.add_argument("--base-tol", type=float, default=DEFAULT_BASE_TOL,
                    help="base relative tolerance before the 1/sqrt("
                         "repeats) noise scaling (default 0.25)")
    args = ap.parse_args(argv)

    history_path = args.history or args.root / "BENCH_history.jsonl"
    history = load_history(history_path)
    payload_files = [f for f in sorted(args.root.glob("BENCH_*.json"))
                     if f.name != "BENCH_index.json"]
    if not payload_files:
        print(f"error: no BENCH_*.json under {args.root}", file=sys.stderr)
        return 2

    all_rows: List[dict] = []
    undeclared = []
    for f in payload_files:
        try:
            payload = json.loads(f.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"error: unreadable payload {f}: {e}", file=sys.stderr)
            return 2
        if not payload.get("headline"):
            undeclared.append(f.name)
            continue
        all_rows.extend(compare_payload(payload, history, args.base_tol))

    header = (f"{'benchmark':<22} {'metric':<26} {'prev':>12} {'cur':>12} "
              f"{'delta%':>8} {'tol%':>6}  status")
    print(header)
    print("-" * len(header))
    for r in all_rows:
        delta = "-" if r["delta_pct"] is None else f"{r['delta_pct']:+.1f}"
        tol = "-" if r["tol_pct"] is None else f"{r['tol_pct']:.1f}"
        print(f"{r['benchmark']:<22} {r['metric']:<26} {_fmt(r['prev'])} "
              f"{_fmt(r['cur'])} {delta:>8} {tol:>6}  {r['status']}")
    if undeclared:
        print(f"(no headline declared: {', '.join(undeclared)})")

    regressions = [r for r in all_rows if r["status"] == "REGRESSION"]
    if regressions:
        print(f"\n{len(regressions)} regression(s) vs {history_path}",
              file=sys.stderr)
        return 1
    print(f"\nno regressions ({len(all_rows)} metric(s) checked vs "
          f"{history_path})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
