"""Fleet demo: a P=4 multi-process fleet survives a SIGKILL mid-buffer.

Spawns four worker processes (one per GFL server) behind the selected
transport, SIGKILLs one of them at a tick where its buffer holds unflushed
folded contributions, and lets the coordinator's heartbeat/retry machinery
restart it from its write-ahead checkpoint.  Because every random draw is
pure in ``(seed, server, tick/version)`` and checkpoints are published
crash-atomically BEFORE replies leave the worker, the restarted server
resumes with zero lost folded contributions: the killed run's flush
schedule, q-ledgers and MSD trajectory are identical to the never-killed
twin's — which this script asserts, then reports per-transport throughput
and recovery latency to ``BENCH_fleet.json`` (regression-gated by
``benchmarks/compare.py``).

    PYTHONPATH=src python examples/fleet_demo.py                  # filelog
    PYTHONPATH=src python examples/fleet_demo.py --transport socket
    PYTHONPATH=src python examples/fleet_demo.py \
        --telemetry jsonl:runs/fleet_demo.jsonl   # then: watch --once

The nightly ``fleet_chaos`` CI job runs exactly this plus a
``python -m repro.telemetry.watch --once`` pass over the emitted ``fleet``
stream (docs/fleet.md).
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile

import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO_ROOT)          # benchmarks.meta (write_bench)

from repro.core.fleet import FleetProblem, chaos_run, run_fleet  # noqa: E402
from repro.telemetry import session  # noqa: E402

# buffer=6 with events=4/tick: buf_n is 4 (mid-buffer) on even ticks —
# killing at tick 2 destroys unflushed folded contributions unless the
# write-ahead checkpoint brings them back
KILL_TICK = 2
KILL_SERVER = 2


def build_problem() -> FleetProblem:
    return FleetProblem(P=4, K=16, n=12, buffer=6, events=4,
                        sigma_g=0.2, seed=3)


def chaos(prob: FleetProblem, transport: str, ticks: int, root: str):
    return chaos_run(prob, f"fleet:transport={transport},timeout=5",
                     ticks=ticks, ckpt_root=root,
                     kill_at={KILL_TICK: [KILL_SERVER]})


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--transport", default="filelog",
                    choices=("inproc", "filelog", "socket"))
    ap.add_argument("--ticks", type=int, default=10)
    ap.add_argument("--telemetry", default="",
                    help="sink spec for the coordinator's 'fleet' stream, "
                         "e.g. jsonl:runs/fleet_demo.jsonl")
    ap.add_argument("--no-bench", action="store_true",
                    help="skip writing BENCH_fleet.json")
    args = ap.parse_args(argv)

    prob = build_problem()
    print(f"fleet demo: P={prob.P} servers over '{args.transport}', "
          f"SIGKILL worker{KILL_SERVER} at tick {KILL_TICK} "
          f"(mid-buffer), {args.ticks} ticks")

    with tempfile.TemporaryDirectory(prefix="fleet_demo_") as root:
        if args.telemetry:
            with session(args.telemetry):
                out = chaos(prob, args.transport, args.ticks, root)
        else:
            out = chaos(prob, args.transport, args.ticks, root)

    clean, faulted = out.clean, out.faulted
    print(f"  clean   : msd[-1]={clean.msd[-1]:.6f}  "
          f"flushes={int(clean.flushed.sum())}  "
          f"{clean.ticks_per_s:.2f} ticks/s")
    print(f"  faulted : msd[-1]={faulted.msd[-1]:.6f}  "
          f"flushes={int(faulted.flushed.sum())}  "
          f"{faulted.ticks_per_s:.2f} ticks/s  "
          f"kills={faulted.kills} restarts={faulted.restarts} "
          f"recovery={faulted.recovery_s[0] if faulted.recovery_s else 0:.2f}s")

    # the robustness contract: the kill cost NOTHING
    assert faulted.kills == 1 and faulted.restarts >= 1, \
        "the kill/restart path was never exercised"
    assert np.array_equal(faulted.flushed, clean.flushed), \
        "flush schedules diverged: folded contributions were lost"
    assert faulted.q_ledgers == clean.q_ledgers, \
        "worker q-ledgers diverged: privacy accounting would drift"
    assert out.msd_gap < 1e-9, \
        f"faulted run left the clean run's neighborhood (gap={out.msd_gap})"
    print(f"  recovery exact: msd gap {out.msd_gap:.1e}, identical flush "
          f"schedule and q-ledgers")

    # throughput comparison on the never-killed path (inproc threads vs
    # the requested multi-process transport)
    with tempfile.TemporaryDirectory(prefix="fleet_tp_") as root:
        inproc = run_fleet(prob, "fleet", args.ticks,
                           ckpt_root=os.path.join(root, "inproc"))
    tps = {"inproc": inproc.ticks_per_s, args.transport: clean.ticks_per_s}
    for name, v in sorted(tps.items()):
        print(f"  throughput[{name}]: {v:.2f} ticks/s")

    if not args.no_bench:
        from benchmarks.meta import write_bench
        recovery = faulted.recovery_s[0] if faulted.recovery_s else 0.0
        headline = {
            f"{args.transport}_ticks_per_sec":
                ("higher", clean.ticks_per_s),
            "recovery_s": ("lower", recovery),
        }
        write_bench(os.path.join(REPO_ROOT, "BENCH_fleet.json"), {
            "benchmark": "fleet_chaos",
            "transport": args.transport,
            "P": prob.P, "ticks": args.ticks,
            "kill_tick": KILL_TICK, "kill_server": KILL_SERVER,
            "msd_clean": float(clean.msd[-1]),
            "msd_faulted": float(faulted.msd[-1]),
            "msd_gap": out.msd_gap,
            "flushes": int(clean.flushed.sum()),
            "restarts": faulted.restarts,
            "retries": faulted.retries,
            "recovery_s": recovery,
            "ticks_per_sec": tps,
        }, headline=headline)
        print("  wrote BENCH_fleet.json "
              "(gate: python benchmarks/compare.py)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
