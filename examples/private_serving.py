"""Serving example: batched prefill + greedy decode with the consensus model.

Demonstrates the serving path of the framework (KV caches, ring buffers for
sliding-window archs, batched requests) on a reduced phi3 config — the same
code that the `decode_32k` / `long_500k` dry-runs lower at production scale.

    PYTHONPATH=src python examples/private_serving.py [--arch phi3-mini-3.8b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    print(f"serving {cfg.name} (window={cfg.sliding_window or 'full'})")

    batch = {"tokens": jax.random.randint(
        jax.random.fold_in(key, 1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.1 * jax.random.normal(
            jax.random.fold_in(key, 2),
            (args.batch, cfg.num_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.fold_in(key, 2),
            (args.batch, cfg.encoder_seq_len, cfg.d_model))

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in "
          f"{t_prefill*1e3:.0f} ms")

    toks = jnp.argmax(logits, axis=-1)
    generated = [toks]
    t0 = time.time()
    for _ in range(args.new_tokens):
        logits, cache = decode(params, toks, cache)
        toks = jnp.argmax(logits, axis=-1)
        generated.append(toks)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    total = args.batch * args.new_tokens
    print(f"decode: {total} tokens in {dt*1e3:.0f} ms "
          f"({total/dt:.0f} tok/s on CPU, reduced config)")
    gen = np.stack([np.asarray(t) for t in generated], axis=1)
    print("sample token ids:", gen[0][:16].tolist())
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print("OK")


if __name__ == "__main__":
    main()
