"""Population-scale demo: a virtual fleet of 100k clients per server.

Walks the population engine end to end on a laptop CPU:

  1. a LAZY synthetic population (no [P, K, N, M] tensor exists — every
     client's shard is a pure function of (data_seed, server, client));
  2. cohort scheduling under a diurnal availability trace, first uniform,
     then gradient-norm importance sampling with unbiased 1/(K pi)
     reweighting;
  3. subsampling-amplified privacy accounting: the same hybrid mechanism,
     but the ledger charged at the realized cohort rate q = L/K instead of
     full participation — the epsilon gap is the amplification win.

    PYTHONPATH=src python examples/population_demo.py
"""
import jax
import numpy as np

from repro.configs.base import GFLConfig
from repro.core.population import estimate_w_ref, run_gfl_population
from repro.core.privacy.mechanism import mechanism_for

P, K, L, ITERS = 8, 100_000, 20, 120


def main():
    print(f"virtual population: P={P} servers x K={K:,} clients/server "
          f"(={P * K:,} clients), cohort L={L} per round "
          f"(q = {L / K:.0e})")

    base = GFLConfig(num_servers=P, clients_per_server=K, clients_sampled=L,
                     topology="hypercube", privacy="hybrid", sigma_g=0.2,
                     mu=0.1, grad_bound=10.0,
                     population="synthetic:hetero,lo=0.5,hi=1.5")

    # reference minimizer: Monte-Carlo client subsample (the fleet itself
    # is never materialized)
    from repro.core.population import population_from_spec
    pop = population_from_spec(base)
    w_ref = estimate_w_ref(pop, sample_clients=64, iters=1500)
    print(f"w_ref (MC over 64/{K:,} clients per server): "
          f"{np.asarray(w_ref).round(3)}")

    print(f"\n{'cohort spec':52s} {'MSD tail':>9s} {'q mean':>8s}")
    from dataclasses import replace
    for cohort in ("uniform",
                   "uniform+trace:diurnal,period=24,min=0.2",
                   "importance,floor=0.2+trace:diurnal,period=24,min=0.2"):
        cfg = replace(base, cohort=cohort)
        res = run_gfl_population(pop, cfg, iters=ITERS, batch_size=10,
                                 seed=1, w_ref=w_ref)
        tail = float(np.mean(res.msd[-12:]))
        print(f"{cohort:52s} {tail:9.5f} {res.q.mean():8.2g}")

    # amplification: same mechanisms, ledger charged at the realized q.
    # Theorem 2's quadratic curve has huge per-release epsilons, where
    # amplification only shaves ln(1/q) per release; the scheduled curve
    # spends small uniform slices, where amplification is the full
    # multiplicative q win — the regime arXiv:2301.06412 analyzes.
    q = L / K
    print(f"\nprivacy after {ITERS} rounds, full vs amplified (q={q:.0e}):")
    acc = mechanism_for(base).accountant()
    acc.advance(ITERS, q=q)
    print(f"  hybrid / Theorem-2   eps {acc.epsilon():12.1f}   ->  "
          f"eps_amp {acc.amplified_epsilon():12.4f}")
    sched = replace(base, privacy="scheduled", epsilon_target=10.0,
                    epsilon_horizon=ITERS)
    acc_s = mechanism_for(sched).accountant()
    acc_s.advance(ITERS, q=q)
    print(f"  scheduled (eps<=10)  eps {acc_s.epsilon():12.1f}   ->  "
          f"eps_amp {acc_s.amplified_epsilon():12.6f}")
    print("each round only exposes the sampled cohort, so release j is "
          "charged\nln(1 + q(e^eps_j - 1)) instead of eps_j "
          "(docs/population.md).")


if __name__ == "__main__":
    main()
