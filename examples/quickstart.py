"""Quickstart: the paper's Section-V experiment in ~30 seconds.

Runs graph federated learning (P=10 servers x K=50 clients, logistic
regression) under three privacy schemes and prints the steady-state MSD —
reproducing the qualitative Figure-2 result: the hybrid scheme (secure
aggregation + graph-homomorphic noise) tracks the non-private algorithm,
while standard iid-DP noise costs utility.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs.base import GFLConfig
from repro.core.privacy.accountant import PrivacyAccountant, epsilon_at
from repro.core.privacy.mechanism import list_mechanisms
from repro.core.simulate import generate_problem, run_gfl

ITERS = 200
SIGMA = 0.2
MU = 0.1


def main():
    print("generating the paper's synthetic logistic problem "
          "(P=10, K=50, M=2)...")
    prob = generate_problem(jax.random.PRNGKey(0), P=10, K=50, N=100, M=2)
    print(f"  global optimum w* = {np.asarray(prob.w_opt).round(3)}")

    # scheduled gets the SAME total budget the fixed-sigma run spends by the
    # horizon (Theorem 2) — it just spends it linearly instead
    eps_budget = epsilon_at(ITERS, MU, 10.0, SIGMA)
    for scheme in list_mechanisms():       # every registered privacy scheme
        cfg = GFLConfig(num_servers=10, clients_per_server=50,
                        clients_sampled=10, privacy=scheme, sigma_g=SIGMA,
                        mu=MU, topology="full", grad_bound=10.0,
                        epsilon_target=eps_budget, epsilon_horizon=ITERS)
        msd, _ = run_gfl(prob, cfg, iters=ITERS, batch_size=10, seed=1)
        tail = float(np.mean(msd[-20:]))
        print(f"  scheme={scheme:12s}  MSD[0]={msd[0]:.3f}  "
              f"MSD[final]={tail:.5f}")

    acc = PrivacyAccountant(mu=MU, grad_bound=10.0, sigma_g=SIGMA)
    acc.advance(ITERS)
    print(f"privacy ledger after {ITERS} iterations: "
          f"eps({ITERS}) = {acc.epsilon():.1f} "
          f"(Theorem 2; privacy decays quadratically with time)")
    print(f"sigma needed for eps=5 at this horizon: "
          f"{acc.sigma_schedule(ITERS, 5.0):.2f}")


if __name__ == "__main__":
    main()
