"""End-to-end driver: train a (reduced) SmolLM language model with the GFL
protocol for a few hundred steps on synthetic token streams.

This is the paper's algorithm applied to a real transformer: P servers each
average L clients' one-step SGD updates (secure-agg masks cancel), then mix
with graph neighbours under graph-homomorphic Laplace noise.  Loss decreases
while the privacy accountant tracks eps(i).

    PYTHONPATH=src python examples/federated_lm.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.configs.base import GFLConfig
from repro.configs.registry import get_config
from repro.core import gfl
from repro.core.privacy.mechanism import list_mechanisms, mechanism_for
from repro.core.topology import combination_matrix, spectral_gap
from repro.data import TokenStream, federated_token_batches
from repro.models import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--servers", type=int, default=4)
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--privacy", default="hybrid",
                    choices=list_mechanisms())
    ap.add_argument("--sigma", type=float, default=0.01)
    args = ap.parse_args()

    cfg = get_config("smollm-135m").reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params0 = model.init(key)
    flat0, unravel = ravel_pytree(params0)
    D = flat0.size
    print(f"model: {cfg.name}  ({D:,} params)   "
          f"servers={args.servers} clients/round={args.clients}")

    gcfg = GFLConfig(num_servers=args.servers,
                     clients_per_server=args.clients,
                     privacy=args.privacy, sigma_g=args.sigma,
                     mu=0.5, topology="ring", grad_bound=5.0)
    A = combination_matrix("ring", args.servers)
    print(f"ring graph spectral gap lambda = {spectral_gap(A):.3f}")

    def grad_fn(w_flat, batch):
        def loss(w_flat):
            loss_val, _ = model.loss(unravel(w_flat), batch, remat=False)
            return loss_val
        return jax.grad(loss)(w_flat)

    def loss_of(w_flat, batch):
        return model.loss(unravel(w_flat), batch, remat=False)[0]

    step = gfl.make_gfl_step(A, grad_fn, gcfg)
    state = gfl.GFLState(jnp.broadcast_to(flat0, (args.servers, D)),
                         jnp.zeros((), jnp.int32), key)

    stream = TokenStream(vocab=cfg.vocab_size, seed=0)
    # mechanism-aware accountant: the noise profile picks the curve (eps
    # is inf for a zero-noise config — the honest Theorem-2 answer)
    mech = mechanism_for(gcfg)
    tracked = mech.noise_profile().curve != "none"
    acc = mech.accountant()
    eval_batch = federated_token_batches(stream, 99, 0, args.servers, 1, 4,
                                         args.seq)
    eval_b = jax.tree.map(lambda x: x[0, 0], eval_batch)
    eval_loss = jax.jit(loss_of)

    t0 = time.time()
    for i in range(args.steps):
        batch = federated_token_batches(stream, 0, i, args.servers,
                                        args.clients, 2, args.seq)
        state = step(state, batch)
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            wc = gfl.centroid(state.params)
            lv = float(eval_loss(wc, eval_b))
            eps = acc.advance(max(args.steps // 10, 1)) \
                if tracked else float("nan")
            print(f"step {i:4d}  centroid eval loss {lv:.4f}  "
                  f"eps(i)={eps:9.1f}  ({time.time()-t0:.0f}s)")
    print("done: loss should have decreased from ~ln(V) while training "
          "stayed private at the recorded eps schedule")


if __name__ == "__main__":
    main()
