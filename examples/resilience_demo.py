"""Resilience demo: the paper's robustness claim, made executable.

Runs the Section-V logistic problem under increasingly hostile failure
regimes — i.i.d. link drops, correlated server outages, straggling servers
re-announcing stale psi, and mid-round client dropout with dropout-safe
secure aggregation — and prints, per regime, the steady-state MSD and the
realized spectral-gap trajectory statistics (lambda_i = rho(A_i - 11^T/P):
0 = instant consensus, -> 1 = no mixing).  Every per-round effective
matrix A_i stays symmetric, doubly stochastic and connected (Assumption 1),
so the protocol keeps its guarantees while the topology churns.

    PYTHONPATH=src python examples/resilience_demo.py
"""
import jax
import numpy as np

from repro.configs.base import GFLConfig
from repro.core.resilience import TopologyProcess, parse_fault_spec
from repro.core.simulate import (
    base_combination_matrix,
    generate_problem,
    run_gfl,
)

ITERS = 150

REGIMES = [
    ("failure-free", "none"),
    ("flaky links", "links:0.2"),
    ("links + outages", "links:0.1+outage:0.1"),
    ("stragglers (stale<=3)", "straggler:0.3,stale=3"),
    ("client dropout 30%", "dropout:0.3"),
    ("everything at once",
     "links:0.1+outage:0.05+straggler:0.2,stale=2+dropout:0.2"),
]


def main():
    print("generating the paper's synthetic logistic problem "
          "(P=8, K=20, hypercube servers)...")
    prob = generate_problem(jax.random.PRNGKey(0), P=8, K=20)

    print(f"{'regime':24s} {'fault spec':>44s} {'MSD tail':>9s} "
          f"{'gap mean':>9s} {'gap worst':>9s}")
    for name, spec in REGIMES:
        cfg = GFLConfig(num_servers=8, clients_per_server=20,
                        clients_sampled=5, topology="hypercube",
                        privacy="hybrid", sigma_g=0.2, mu=0.1,
                        grad_bound=10.0, fault=spec, topology_seed=7)
        msd, _, gaps = run_gfl(prob, cfg, iters=ITERS, batch_size=10,
                               seed=1, record_gaps=True)
        tail = float(np.mean(msd[-15:]))
        print(f"{name:24s} {spec:>44s} {tail:9.5f} "
              f"{gaps.mean():9.3f} {gaps.max():9.3f}")

    # the process itself is a first-class object: realize rounds directly
    fault = parse_fault_spec("links:0.3")
    proc = TopologyProcess(
        base_combination_matrix(GFLConfig(topology="hypercube"), 8),
        fault, seed=7)
    from repro.core.topology import spectral_gap
    real = proc.realize(0)
    dropped = int((proc.base_mask & ~real.link_mask).sum() // 2)
    total = int(proc.base_mask.sum() // 2)
    print(f"\nround-0 realization under {fault.to_spec()}: "
          f"{dropped} of {total} links down, "
          f"spectral gap {real.gap:.3f} "
          f"(base {spectral_gap(proc.base_A):.3f})")
    print("every realized A_i satisfies Assumption 1 — symmetric, doubly "
          "stochastic, connected — so convergence degrades gracefully "
          "instead of breaking.")


if __name__ == "__main__":
    main()
