"""Privacy-utility tradeoff: sweep the target epsilon, derive the Theorem-2
noise schedule, and measure the utility (steady-state MSD) of the hybrid vs
iid schemes at that noise level.

    PYTHONPATH=src python examples/dp_sweep.py
"""
import jax
import numpy as np

from repro.configs.base import GFLConfig
from repro.core.privacy.accountant import sigma_for_epsilon
from repro.core.simulate import generate_problem, run_gfl

ITERS = 150
MU = 0.1
B = 10.0


def main():
    prob = generate_problem(jax.random.PRNGKey(0), P=10, K=50)
    print(f"{'eps target':>10} | {'sigma (Thm 2)':>13} | "
          f"{'MSD hybrid':>11} | {'MSD iid':>9}")
    print("-" * 55)
    for eps in (1000.0, 5000.0, 20000.0):
        sigma = sigma_for_epsilon(ITERS, MU, B, eps)
        row = []
        for scheme in ("hybrid", "iid_dp"):
            cfg = GFLConfig(num_servers=10, clients_per_server=50,
                            clients_sampled=10, privacy=scheme,
                            sigma_g=sigma, mu=MU, topology="full",
                            grad_bound=B)
            msd, _ = run_gfl(prob, cfg, iters=ITERS, batch_size=10, seed=2)
            row.append(float(np.mean(msd[-15:])))
        print(f"{eps:>10.0f} | {sigma:>13.3f} | {row[0]:>11.5f} | "
              f"{row[1]:>9.5f}")
    print("\nhybrid utility is ~flat in sigma (the noise lies in the "
          "averaging nullspace); iid utility degrades as Theorem 1's "
          "O(mu + 1/mu) sigma^2 term predicts")


if __name__ == "__main__":
    main()
