"""Privacy-utility tradeoff: sweep the target epsilon, derive each
mechanism's accountant-curve noise schedule, and measure the utility
(steady-state MSD) of the registered private schemes at that noise level.

The hybrid and gaussian_dp rows use the fixed sigma their accountant curve
demands for eps at the horizon (Theorem 2 / Gaussian mechanism); the
scheduled row spends the budget per-step via the dead-no-more
``epsilon_target`` knob and needs no precomputed sigma at all.

    PYTHONPATH=src python examples/dp_sweep.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs.base import GFLConfig
from repro.core.privacy.mechanism import get_mechanism, mechanism_for
from repro.core.simulate import generate_problem, run_gfl

ITERS = 150
MU = 0.1
B = 10.0

SCHEMES = ("hybrid", "gaussian_dp", "iid_dp", "scheduled")


def main():
    prob = generate_problem(jax.random.PRNGKey(0), P=10, K=50)
    # each fixed-sigma scheme derives its OWN sigma from its accountant
    # curve (gaussian_dp's is ~3.4x hybrid's); the column shows hybrid's
    header = " | ".join(f"{s:>12}"
                        for s in ("eps target", "sigma(hyb)") + SCHEMES)
    print(header)
    print("-" * len(header))
    for eps in (1000.0, 5000.0, 20000.0):
        row = []
        sigma_shown = 0.0
        for scheme in SCHEMES:
            cfg = GFLConfig(num_servers=10, clients_per_server=50,
                            clients_sampled=10, privacy=scheme,
                            sigma_g=0.0, mu=MU, topology="full",
                            grad_bound=B, epsilon_target=eps,
                            epsilon_horizon=ITERS)
            if scheme != "scheduled":
                # fixed sigma from the mechanism's own accountant curve
                sigma = mechanism_for(cfg).accountant().sigma_schedule(
                    ITERS, eps)
                cfg = dataclasses.replace(cfg, sigma_g=sigma)
                if scheme == "hybrid":
                    sigma_shown = sigma
            msd, _ = run_gfl(prob, cfg, iters=ITERS, batch_size=10, seed=2)
            row.append(float(np.mean(msd[-15:])))
        cells = " | ".join(f"{v:>12.5f}" for v in row)
        print(f"{eps:>12.0f} | {sigma_shown:>12.3f} | {cells}")
    print("\nhybrid/gaussian_dp utility is ~flat in sigma (the noise lies "
          "in the averaging nullspace); iid utility degrades as Theorem 1's "
          "O(mu + 1/mu) sigma^2 term predicts; scheduled spends the same "
          "budget linearly instead of quadratically")
    # show the registry spec syntax while we're here
    cfg = GFLConfig(privacy="scheduled:gaussian_dp", epsilon_target=1000.0,
                    epsilon_horizon=ITERS, mu=MU, grad_bound=B)
    prof = get_mechanism(cfg.privacy, cfg).noise_profile()
    print(f"\nscheduled:gaussian_dp profile: curve={prof.curve} "
          f"distribution={prof.distribution} "
          f"sigma@horizon={prof.server_sigma:.2f}")


if __name__ == "__main__":
    main()
